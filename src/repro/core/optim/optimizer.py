"""First-order optimizers (paper §4.2): defined over Variable/Tensor ops so
they are open to experimentation (distributed updates, in-place tricks).

Two call styles, one implementation:

* imperative (paper Listing 9): ``opt.step(); opt.zeroGrad()`` over a
  module's Variables;
* functional (production loop): ``new_params, new_state = opt.apply(params,
  grads, state)`` over pytrees — this is the form the pjit'd trainer uses,
  and state entries carry sharding rules so optimizer state can be
  ZeRO-sharded across the mesh.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from ..autograd import Variable


class Optimizer:
    """Base: functional ``init``/``apply`` + imperative Variable bridge."""

    def __init__(self, params: Sequence[Variable] | None = None,
                 state_dtype=None):
        self._vars = list(params) if params is not None else None
        self._state = None
        self.state_dtype = state_dtype
        self.step_count = 0

    # -- functional API -------------------------------------------------------
    def init(self, params: Any) -> Any:
        return jax.tree.map(self._init_leaf, params)

    def apply(self, params: Any, grads: Any, state: Any,
              lr: float | jax.Array) -> tuple[Any, Any]:
        self.step_count += 1
        count = self.step_count
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state)
        new_p, new_s = [], []
        for p, g, s in zip(flat_p, flat_g, flat_s):
            np_, ns_ = self._update_leaf(p, g, s, lr, count)
            new_p.append(np_)
            new_s.append(ns_)
        return (jax.tree.unflatten(treedef, new_p),
                jax.tree.unflatten(treedef, new_s))

    def apply_with_count(self, params, grads, state, lr, count):
        """Pure form for jit: caller carries the step count."""
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state)
        out = [self._update_leaf(p, g, s, lr, count)
               for p, g, s in zip(flat_p, flat_g, flat_s)]
        return (jax.tree.unflatten(treedef, [o[0] for o in out]),
                jax.tree.unflatten(treedef, [o[1] for o in out]))

    def _init_leaf(self, p) -> Any:
        raise NotImplementedError

    def _update_leaf(self, p, g, s, lr, count) -> tuple[Any, Any]:
        raise NotImplementedError

    # -- imperative API (paper Listing 9) ----------------------------------------
    def step(self, lr: float | None = None) -> None:
        if self._vars is None:
            raise RuntimeError("imperative step() needs params at __init__")
        if self._state is None:
            self._state = [self._init_leaf(v.data) for v in self._vars]
        self.step_count += 1
        use_lr = lr if lr is not None else getattr(self, "lr", None)
        for i, v in enumerate(self._vars):
            if v.grad is None:
                continue
            v.data, self._state[i] = self._update_leaf(
                v.data, v.grad, self._state[i], use_lr, self.step_count)

    def zeroGrad(self) -> None:  # noqa: N802 - paper-faithful name
        if self._vars is not None:
            for v in self._vars:
                v.zero_grad()

    zero_grad = zeroGrad

    def state_sharding_like(self, param_sharding: Any) -> Any:
        """Map a param's sharding rule onto this optimizer's state for it.

        Moment-style states are shaped like the param, so they inherit the
        param's logical axes — this is what lets the trainer ZeRO-shard
        optimizer state across the data axis.
        """
        return jax.tree.map(lambda _: param_sharding,
                            self._init_leaf(jnp.zeros(())))

    def _cast(self, x):
        return x if self.state_dtype is None else x.astype(self.state_dtype)


class SGD(Optimizer):
    def __init__(self, params=None, lr: float = 0.01, momentum: float = 0.0,
                 weight_decay: float = 0.0, nesterov: bool = False, **kw):
        super().__init__(params, **kw)
        self.lr, self.momentum = lr, momentum
        self.weight_decay, self.nesterov = weight_decay, nesterov

    def _init_leaf(self, p):
        if self.momentum == 0.0:
            return ()
        return self._cast(jnp.zeros_like(p))

    def _update_leaf(self, p, g, s, lr, count):
        lr = self.lr if lr is None else lr
        if self.weight_decay:
            g = g + self.weight_decay * p
        if self.momentum == 0.0:
            return p - lr * g.astype(p.dtype), ()
        buf = self.momentum * s + g.astype(s.dtype)
        d = (g + self.momentum * buf.astype(g.dtype)) if self.nesterov \
            else buf.astype(g.dtype)
        return p - lr * d.astype(p.dtype), buf


SGDOptimizer = SGD  # paper-faithful alias (Listing 9)


class Adam(Optimizer):
    def __init__(self, params=None, lr: float = 1e-3, b1: float = 0.9,
                 b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.0, **kw):
        super().__init__(params, **kw)
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.weight_decay = weight_decay

    def _init_leaf(self, p):
        return {"m": self._cast(jnp.zeros_like(p)),
                "v": self._cast(jnp.zeros_like(p))}

    def _update_leaf(self, p, g, s, lr, count):
        lr = self.lr if lr is None else lr
        g32 = g.astype(jnp.float32)
        m = self.b1 * s["m"].astype(jnp.float32) + (1 - self.b1) * g32
        v = self.b2 * s["v"].astype(jnp.float32) + (1 - self.b2) * g32 * g32
        mhat = m / (1 - self.b1 ** count)
        vhat = v / (1 - self.b2 ** count)
        update = mhat / (jnp.sqrt(vhat) + self.eps)
        if self.weight_decay:
            update = update + self.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, {"m": self._cast(m), "v": self._cast(v)}


class AdamW(Adam):
    """Decoupled weight decay (the production-default optimizer)."""

    def __init__(self, params=None, lr: float = 1e-3, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, **kw):
        super().__init__(params, lr=lr, b1=b1, b2=b2, eps=eps,
                         weight_decay=0.0, **kw)
        self.decoupled_wd = weight_decay

    def _update_leaf(self, p, g, s, lr, count):
        lr_v = self.lr if lr is None else lr
        new_p, new_s = super()._update_leaf(p, g, s, lr, count)
        if self.decoupled_wd:
            new_p = new_p - (lr_v * self.decoupled_wd * p.astype(
                jnp.float32)).astype(p.dtype)
        return new_p, new_s


class Adafactor(Optimizer):
    """Factored second moment — the memory-frugal option for huge models."""

    def __init__(self, params=None, lr: float = 1e-2, decay: float = 0.8,
                 eps: float = 1e-30, **kw):
        super().__init__(params, **kw)
        self.lr, self.decay, self.eps = lr, decay, eps

    def _init_leaf(self, p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    def _update_leaf(self, p, g, s, lr, count):
        lr = self.lr if lr is None else lr
        g32 = g.astype(jnp.float32)
        beta = 1.0 - count ** (-self.decay)
        g2 = g32 * g32 + self.eps
        if p.ndim >= 2:
            vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
            vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
            rfac = (vr / vr.mean(axis=-1, keepdims=True))[..., None]
            update = g32 / (jnp.sqrt(rfac * vc[..., None, :]) + 1e-12)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta * s["v"] + (1 - beta) * g2
            update = g32 / (jnp.sqrt(v) + 1e-12)
            new_s = {"v": v}
        # update clipping (rms <= 1)
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-12)
        update = update / jnp.maximum(1.0, rms)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), new_s


# -- gradient utilities ---------------------------------------------------------

def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(
        g.dtype), grads), gnorm


# -- LR schedules ---------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        frac = (step - warmup) / jnp.maximum(1.0, total - warmup)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def linear_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        lin = base_lr * jnp.clip((total - step) / jnp.maximum(
            1.0, total - warmup), 0.0, 1.0)
        return jnp.where(step < warmup, warm, lin)

    return lr
