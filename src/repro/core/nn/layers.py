"""Neural building blocks (paper §4.2: activations, norms, regularizers,
losses, …) — compact reference implementations over the tensor dispatch +
tape autograd, so they inherit backend swaps and autograd customization.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..autograd import Variable
from ..autograd import functions as F
from ..tensor import ops
from .module import Module, Sequential


class _RngMixin:
    """Deterministic per-module RNG stream for dropout etc."""

    _rng_counter = 0

    @classmethod
    def _next_key(cls):
        cls._rng_counter += 1
        return jax.random.PRNGKey(cls._rng_counter)


def _uniform_init(key, shape, fan_in):
    bound = 1.0 / math.sqrt(fan_in) if fan_in > 0 else 1.0
    return jax.random.uniform(key, shape, jnp.float32, -bound, bound)


class Linear(Module):
    def __init__(self, in_features: int, out_features: int, bias: bool = True,
                 key=None):
        super().__init__()
        key = key if key is not None else _RngMixin._next_key()
        k1, k2 = jax.random.split(key)
        self.weight = Variable(_uniform_init(k1, (in_features, out_features),
                                             in_features), requires_grad=True)
        if bias:
            self.bias = Variable(jnp.zeros((out_features,)),
                                 requires_grad=True)
        else:
            object.__setattr__(self, "bias", None)

    def forward(self, x: Variable) -> Variable:
        out = F.matmul(x, self.weight)
        if self.bias is not None:
            out = F.add(out, self.bias)
        return out


class Embedding(Module):
    def __init__(self, num_embeddings: int, dim: int, key=None):
        super().__init__()
        key = key if key is not None else _RngMixin._next_key()
        self.weight = Variable(
            jax.random.normal(key, (num_embeddings, dim)) * 0.02,
            requires_grad=True)

    def forward(self, ids) -> Variable:
        return F.embedding(self.weight, ids)


class LayerNorm(Module):
    def __init__(self, dim: int, eps: float = 1e-5):
        super().__init__()
        self.weight = Variable(jnp.ones((dim,)), requires_grad=True)
        self.bias = Variable(jnp.zeros((dim,)), requires_grad=True)
        object.__setattr__(self, "eps", eps)

    def forward(self, x: Variable) -> Variable:
        return F.layer_norm(x, self.weight, self.bias, self.eps)


class RMSNorm(Module):
    def __init__(self, dim: int, eps: float = 1e-6):
        super().__init__()
        self.weight = Variable(jnp.ones((dim,)), requires_grad=True)
        object.__setattr__(self, "eps", eps)

    def forward(self, x: Variable) -> Variable:
        return F.rms_norm(x, self.weight, self.eps)


class Dropout(Module):
    """Paper Listing 6, ported verbatim in behavior."""

    def __init__(self, drop_ratio: float = 0.5):
        super().__init__()
        object.__setattr__(self, "ratio", drop_ratio)

    def forward(self, x: Variable) -> Variable:
        if self.training and self.ratio > 0.0:
            return F.dropout(x, self.ratio, _RngMixin._next_key())
        return x


class ReLU(Module):
    def forward(self, x):
        return F.relu(x)


class GELU(Module):
    def forward(self, x):
        return F.gelu(x)


class SiLU(Module):
    def forward(self, x):
        return F.silu(x)


class Tanh(Module):
    def forward(self, x):
        return F.tanh(x)


class LogSoftmax(Module):
    def __init__(self, axis: int = -1):
        super().__init__()
        object.__setattr__(self, "axis", axis)

    def forward(self, x):
        return F.log_softmax(x, axis=self.axis)


class Conv2D(Module):
    """NHWC conv (paper Listing 8 signature flavor)."""

    def __init__(self, in_channels: int, out_channels: int, kw: int, kh: int,
                 sx: int = 1, sy: int = 1, padding: str = "SAME", key=None):
        super().__init__()
        key = key if key is not None else _RngMixin._next_key()
        fan_in = in_channels * kw * kh
        self.weight = Variable(
            _uniform_init(key, (kh, kw, in_channels, out_channels), fan_in),
            requires_grad=True)
        self.bias = Variable(jnp.zeros((out_channels,)), requires_grad=True)
        object.__setattr__(self, "stride", (sy, sx))
        object.__setattr__(self, "padding", padding)

    def forward(self, x: Variable) -> Variable:
        out = F.conv2d(x, self.weight, stride=self.stride,
                       padding=self.padding)
        return F.add(out, self.bias)


class Pool2D(Module):
    """Max pool via lifted lax.reduce_window."""

    def __init__(self, kw: int, kh: int, sx: int, sy: int):
        super().__init__()
        object.__setattr__(self, "window", (1, kh, kw, 1))
        object.__setattr__(self, "stride", (1, sy, sx, 1))

    def forward(self, x: Variable) -> Variable:
        window, stride = self.window, self.stride

        def pool(v):
            return jax.lax.reduce_window(
                v, -jnp.inf, jax.lax.max, window, stride, "VALID")

        return F.lift(pool, name="pool2d")(x)


class View(Module):
    def __init__(self, shape):
        super().__init__()
        object.__setattr__(self, "shape", tuple(shape))

    def forward(self, x: Variable) -> Variable:
        return F.reshape(x, self.shape)


class MultiHeadAttention(Module):
    """Reference MHA for the core stack (BERT-like/ViT-like benchmarks)."""

    def __init__(self, dim: int, num_heads: int, key=None):
        super().__init__()
        key = key if key is not None else _RngMixin._next_key()
        ks = jax.random.split(key, 4)
        self.wq = Linear(dim, dim, key=ks[0])
        self.wk = Linear(dim, dim, key=ks[1])
        self.wv = Linear(dim, dim, key=ks[2])
        self.wo = Linear(dim, dim, key=ks[3])
        object.__setattr__(self, "num_heads", num_heads)
        object.__setattr__(self, "head_dim", dim // num_heads)

    def forward(self, x: Variable, mask=None) -> Variable:
        b, s, d = x.shape
        h, hd = self.num_heads, self.head_dim

        def split(v):
            return F.transpose(F.reshape(v, (b, s, h, hd)), (0, 2, 1, 3))

        q, k, v = split(self.wq(x)), split(self.wk(x)), split(self.wv(x))
        kt = F.transpose(k, (0, 1, 3, 2))
        scores = F.mul(F.matmul(q, kt),
                       Variable(ops.full((), 1.0 / math.sqrt(hd))))
        if mask is not None:
            scores = F.add(scores, Variable(mask))
        attn = F.softmax(scores, axis=-1)
        out = F.matmul(attn, v)
        out = F.reshape(F.transpose(out, (0, 2, 1, 3)), (b, s, d))
        return self.wo(out)


class TransformerBlock(Module):
    def __init__(self, dim: int, num_heads: int, ff_mult: int = 4, key=None):
        super().__init__()
        key = key if key is not None else _RngMixin._next_key()
        ks = jax.random.split(key, 3)
        self.ln1 = LayerNorm(dim)
        self.attn = MultiHeadAttention(dim, num_heads, key=ks[0])
        self.ln2 = LayerNorm(dim)
        self.ff1 = Linear(dim, dim * ff_mult, key=ks[1])
        self.ff2 = Linear(dim * ff_mult, dim, key=ks[2])

    def forward(self, x: Variable, mask=None) -> Variable:
        x = F.add(x, self.attn(self.ln1(x), mask=mask))
        return F.add(x, self.ff2(F.gelu(self.ff1(self.ln2(x)))))


# -- losses -------------------------------------------------------------------

def categoricalCrossEntropy(logits: Variable, target) -> Variable:  # noqa: N802
    """Paper-faithful name (Listing 9)."""
    return F.cross_entropy(logits, target)


def mse_loss(pred: Variable, target) -> Variable:
    t = target if isinstance(target, Variable) else Variable(target)
    d = F.sub(pred, t)
    return F.mean(F.mul(d, d))
