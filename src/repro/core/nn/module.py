"""MODULE abstraction (paper §4.2, A.4.2).

Modules recursively store parameters (Variables) and child modules,
"communicate by exchanging Tensor data, and are composed functionally or
imperatively".  Serialization follows the paper's FL_SAVE_LOAD flavor via
``state_dict``/``load_state_dict`` (npz on disk).
"""

from __future__ import annotations

from typing import Any, Callable, Iterator

import jax
import numpy as np

from ..autograd import Variable


class Module:
    def __init__(self):
        object.__setattr__(self, "_params", {})
        object.__setattr__(self, "_children", {})
        object.__setattr__(self, "_train", True)

    # -- registration -------------------------------------------------------
    def __setattr__(self, name: str, value: Any) -> None:
        if isinstance(value, Variable):
            self._params[name] = value
        elif isinstance(value, Module):
            self._children[name] = value
        object.__setattr__(self, name, value)

    def register_param(self, name: str, value: Variable) -> Variable:
        setattr(self, name, value)
        return value

    # -- traversal ------------------------------------------------------------
    def params(self) -> list[Variable]:
        """All parameters, depth-first (paper: ``model.params()``)."""
        out = list(self._params.values())
        for child in self._children.values():
            out.extend(child.params())
        return out

    def named_params(self, prefix: str = "") -> Iterator[tuple[str, Variable]]:
        for name, p in self._params.items():
            yield (f"{prefix}{name}", p)
        for cname, child in self._children.items():
            yield from child.named_params(prefix=f"{prefix}{cname}.")

    def modules(self) -> Iterator["Module"]:
        yield self
        for child in self._children.values():
            yield from child.modules()

    # -- train/eval mode --------------------------------------------------------
    def train(self, mode: bool = True) -> "Module":
        for m in self.modules():
            object.__setattr__(m, "_train", mode)
        return self

    def eval(self) -> "Module":
        return self.train(False)

    @property
    def training(self) -> bool:
        return self._train

    # -- forward ----------------------------------------------------------------
    def forward(self, *args, **kwargs):
        raise NotImplementedError

    def __call__(self, *args, **kwargs):
        return self.forward(*args, **kwargs)

    # -- grads --------------------------------------------------------------------
    def zero_grad(self) -> None:
        for p in self.params():
            p.zero_grad()

    # -- serialization (FL_SAVE_LOAD analog) -----------------------------------------
    def state_dict(self) -> dict[str, Any]:
        return {name: np.asarray(p.tensor())
                for name, p in self.named_params()}

    def load_state_dict(self, state: dict[str, Any], strict: bool = True) -> None:
        own = dict(self.named_params())
        missing = set(own) - set(state)
        unexpected = set(state) - set(own)
        if strict and (missing or unexpected):
            raise KeyError(f"state mismatch: missing={sorted(missing)} "
                           f"unexpected={sorted(unexpected)}")
        for name, value in state.items():
            if name in own:
                own[name].data = jax.numpy.asarray(value)

    def save(self, path: str) -> None:
        np.savez(path, **self.state_dict())

    def load(self, path: str) -> None:
        with np.load(path) as data:
            self.load_state_dict({k: data[k] for k in data.files})

    # -- functional bridge -------------------------------------------------------------
    def param_pytree(self) -> dict[str, Any]:
        return {name: p.data for name, p in self.named_params()}

    def set_param_pytree(self, tree: dict[str, Any]) -> None:
        own = dict(self.named_params())
        for name, value in tree.items():
            own[name].data = value


class Container(Module):
    """Wraps an arbitrary collection of modules (paper A.4.2)."""

    def __init__(self, *modules: Module):
        super().__init__()
        for i, m in enumerate(modules):
            setattr(self, f"m{i}", m)
        object.__setattr__(self, "_order", [f"m{i}" for i in range(len(modules))])

    def __iter__(self):
        return (self._children[n] for n in self._order)

    def __len__(self):
        return len(self._order)

    def __getitem__(self, i):
        return self._children[self._order[i]]


class Sequential(Container):
    """Forwards data through modules in order (paper A.4.2, Listing 8)."""

    def __init__(self, *modules: Module):
        super().__init__(*modules)

    def add(self, module: Module) -> "Sequential":
        name = f"m{len(self._order)}"
        setattr(self, name, module)
        self._order.append(name)
        return self

    def forward(self, x):
        for m in self:
            x = m(x)
        return x


class Lambda(Module):
    """Wraps a pure function of Variables as a Module."""

    def __init__(self, fn: Callable, name: str = "lambda"):
        super().__init__()
        object.__setattr__(self, "_fn", fn)
        object.__setattr__(self, "_name", name)

    def forward(self, *args, **kwargs):
        return self._fn(*args, **kwargs)
