from .module import Module, Container, Sequential, Lambda
from .layers import (Linear, Embedding, LayerNorm, RMSNorm, Dropout, ReLU,
                     GELU, SiLU, Tanh, LogSoftmax, Conv2D, Pool2D, View,
                     MultiHeadAttention, TransformerBlock,
                     categoricalCrossEntropy, mse_loss)

__all__ = ["Module", "Container", "Sequential", "Lambda", "Linear",
           "Embedding", "LayerNorm", "RMSNorm", "Dropout", "ReLU", "GELU",
           "SiLU", "Tanh", "LogSoftmax", "Conv2D", "Pool2D", "View",
           "MultiHeadAttention", "TransformerBlock",
           "categoricalCrossEntropy", "mse_loss"]
