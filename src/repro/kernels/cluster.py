"""Generated cluster kernels for the compiler's fused regions.

The compiler's fusion/matcher passes partition a traced graph into
clusters of four kinds; this module synthesizes or dispatches one kernel
per cluster:

* ``elementwise`` / ``reduction`` — the body is generated from the
  cluster's op list (:func:`make_body`), reading every external input once
  from VMEM, running the region's ops on register values, and writing each
  external output once.  That is the ArrayFire-JIT payoff (paper §4.1.1,
  Fig. 2) made concrete: N dispatches collapse into a single kernel whose
  arithmetic intensity grows with the cluster.  Reduction-tailed regions
  (softmax denominators, mean chains) ride the same whole-array kernel —
  the body replays the ops' own closures, so mixed shapes are exact.
* ``epilogue`` — a 2-D matmul plus its consumer cone; the synthesized
  epilogue body is folded into the tiled matmul kernel's store step
  (:func:`repro.kernels.matmul.matmul_epilogue`).
* ``attention`` — an ``act(scale·(q@kᵀ) + bias) @ v`` match; lowered to
  the parameterized flash-attention template
  (:func:`repro.kernels.flash_attention.attention_template`).

Off-TPU the kernels run under ``interpret=True`` (reference semantics);
shapes/dtypes the TPU lowering cannot tile fall back to a per-cluster
``jax.jit`` of the same synthesized body — fusion is an optimization,
never a correctness constraint.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: dtypes the TPU tiling supports for generated elementwise bodies.
_TPU_DTYPES = (jnp.float32, jnp.bfloat16)


def make_body(nodes: Sequence[Any], input_ids: Sequence[int],
              output_ids: Sequence[int]) -> Callable:
    """Synthesize the cluster's straight-line body: values in, values out.

    Shared by every lowering: the Pallas kernel wraps it with ref
    reads/writes; the jit fallback compiles it directly.
    """
    nodes = tuple(nodes)
    input_ids = tuple(input_ids)
    output_ids = tuple(output_ids)

    def body(*vals):
        env = dict(zip(input_ids, vals))
        for n in nodes:
            env[n.uid] = n.fn(*[env[d] for d in n.inputs])
        return tuple(env[o] for o in output_ids)

    body.__name__ = f"cluster_{'_'.join(n.op for n in nodes[:4])}"
    return body


def pallas_supported(nodes: Sequence[Any], input_nodes: Sequence[Any],
                     on_tpu: bool) -> bool:
    """Can this elementwise/reduction cluster become one ``pallas_call``?

    Off-TPU the whole-array kernel replays the body under interpret mode,
    which is exact for any mix of shapes (implicit broadcasting,
    keepdims/keepdims-less reductions); only rank-0 values are kept on the
    jit path.  On TPU the tiling is conservative: one common VPU-tileable
    shape and supported dtypes.
    """
    shapes = {tuple(n.shape) for n in nodes}
    shapes |= {tuple(n.shape) for n in input_nodes}
    if any(len(s) == 0 for s in shapes):
        return False
    if not on_tpu:
        return True
    if len(shapes) != 1:
        return False
    (shape,) = shapes
    if len(shape) < 2 or shape[-1] % 128 != 0 or shape[-2] % 8 != 0:
        return False
    dtypes = {jnp.dtype(n.dtype) for n in list(nodes) + list(input_nodes)}
    return all(d in _TPU_DTYPES for d in dtypes)


def build_cluster_kernel(nodes: Sequence[Any], input_nodes: Sequence[Any],
                         output_nodes: Sequence[Any],
                         interpret: bool = True) -> Callable:
    """One ``pallas_call`` for the whole cluster.

    Returns ``fn(*input_arrays) -> tuple(output_arrays)``; the kernel body
    is generated from the cluster's op list (see :func:`make_body`).
    """
    body = make_body(nodes, [n.uid for n in input_nodes],
                     [n.uid for n in output_nodes])
    n_in = len(input_nodes)

    def kernel(*refs):
        ins = [r[...] for r in refs[:n_in]]
        outs = body(*ins)
        for r, v in zip(refs[n_in:], outs):
            r[...] = v

    out_shape = [jax.ShapeDtypeStruct(tuple(n.shape), n.dtype)
                 for n in output_nodes]
    call = pl.pallas_call(kernel, out_shape=out_shape, interpret=interpret)

    def run(*vals):
        out = call(*vals)
        return tuple(out)

    run.__name__ = f"pallas_{body.__name__}"
    return run


def build_jit_cluster(nodes: Sequence[Any], input_nodes: Sequence[Any],
                      output_nodes: Sequence[Any]) -> Callable:
    """Per-cluster ``jax.jit`` fallback over the same synthesized body."""
    body = make_body(nodes, [n.uid for n in input_nodes],
                     [n.uid for n in output_nodes])
    return jax.jit(body)


# -- attention clusters ------------------------------------------------------


def attention_supported(input_nodes: Sequence[Any], meta: dict,
                        on_tpu: bool) -> bool:
    """Does the matched attention cluster satisfy the template's tile
    contract?  Off-TPU (interpret) the template takes any match; on TPU
    every dimension must be lane/MXU aligned — otherwise lowering falls
    back to a per-cluster ``jax.jit`` of the cluster body."""
    from repro.kernels.flash_attention import template_supported

    by_uid = {n.uid: n for n in input_nodes}
    q = by_uid.get(meta["q"])
    k = by_uid.get(meta["k"])
    v = by_uid.get(meta["v"])
    if q is None or k is None or v is None:
        return False
    sq, d = q.shape[-2], q.shape[-1]
    sk = k.shape[-2] if meta["k_layout"] == "std" else k.shape[-1]
    dv = v.shape[-1]
    dtypes = [q.dtype, k.dtype, v.dtype]
    return template_supported(sq=sq, sk=sk, d=d, dv=dv, dtypes=dtypes,
                              on_tpu=on_tpu)


def build_attention_cluster(input_nodes: Sequence[Any],
                            output_nodes: Sequence[Any], meta: dict,
                            interpret: bool = True) -> Callable:
    """Lower a matched attention cluster to the flash-style template.

    Maps the cluster's positional inputs to their matched roles (q/k/v and
    the optional additive bias), flattens leading batch dims, and calls
    :func:`repro.kernels.flash_attention.attention_template`.  Unused
    cluster inputs (the uniform consts the matcher peeled — scales, the
    sigmoid ones) are accepted positionally and ignored.
    """
    from repro.kernels import flash_attention as fa

    pos = {n.uid: i for i, n in enumerate(input_nodes)}
    by_uid = {n.uid: n for n in input_nodes}
    q_i, k_i, v_i = pos[meta["q"]], pos[meta["k"]], pos[meta["v"]]
    bias_uid = meta["bias"]
    b_i = pos[bias_uid] if bias_uid is not None else None

    q_shape = tuple(by_uid[meta["q"]].shape)
    k_shape = tuple(by_uid[meta["k"]].shape)
    v_shape = tuple(by_uid[meta["v"]].shape)
    lead = q_shape[:-2]
    n_batch = math.prod(lead) if lead else 1
    sq, d = q_shape[-2], q_shape[-1]
    sk = k_shape[-2] if meta["k_layout"] == "std" else k_shape[-1]
    dv = v_shape[-1]
    out_node = output_nodes[0]
    out_shape, out_dtype = tuple(out_node.shape), out_node.dtype

    bias_spec = "none"
    if bias_uid is not None:
        bshape = tuple(by_uid[bias_uid].shape)
        bias_spec = ("3d" if len(bshape) > 2
                     and any(x != 1 for x in bshape[:-2]) else "2d")

    bq = 128 if sq % 128 == 0 else sq
    bk = 128 if sk % 128 == 0 else sk
    mode, scale = meta["mode"], float(meta["scale"])
    bias_scale, k_layout = float(meta["bias_scale"]), meta["k_layout"]

    def run_impl(*vals):
        q3 = vals[q_i].reshape((n_batch, sq, d))
        if k_layout == "std":
            k3 = vals[k_i].reshape((n_batch, sk, d))
        else:
            k3 = vals[k_i].reshape((n_batch, d, sk))
        v3 = vals[v_i].reshape((n_batch, sk, dv))
        bias = None
        if b_i is not None:
            if bias_spec == "3d":
                bias = jnp.broadcast_to(
                    vals[b_i], lead + (sq, sk)).reshape(n_batch, sq, sk)
            else:
                bias = jnp.broadcast_to(vals[b_i], (sq, sk))
        out = fa.attention_template(
            q3, k3, v3, bias, mode=mode, scale=scale,
            bias_scale=bias_scale, k_layout=k_layout, bias_spec=bias_spec,
            bq=bq, bk=bk, interpret=interpret)
        return (out.reshape(out_shape).astype(out_dtype),)

    run = jax.jit(run_impl)
    return run


# -- epilogue clusters -------------------------------------------------------


def build_epilogue_cluster(nodes: Sequence[Any], input_nodes: Sequence[Any],
                           output_nodes: Sequence[Any], meta: dict,
                           interpret: bool = True) -> Callable:
    """Lower an epilogue cluster: the matmul member runs on the tiled MXU
    kernel, and the synthesized epilogue body executes on each output tile
    at the final K step (:func:`repro.kernels.matmul.matmul_epilogue`)."""
    from repro.kernels import matmul as mm_mod

    mm_uid = meta["matmul"]
    epi_members = [n for n in nodes if n.uid != mm_uid]
    body = make_body(epi_members, [mm_uid, *meta["epi_ext"]],
                     [output_nodes[0].uid])
    pos = {n.uid: i for i, n in enumerate(input_nodes)}
    by_uid = {n.uid: n for n in input_nodes}
    lhs_i, rhs_i = pos[meta["lhs"]], pos[meta["rhs"]]
    extra_is = [pos[u] for u in meta["epi_ext"]]
    extra_shapes = [tuple(by_uid[u].shape) for u in meta["epi_ext"]]

    lhs_n, rhs_n = by_uid[meta["lhs"]], by_uid[meta["rhs"]]
    m, k = lhs_n.shape
    n = rhs_n.shape[1]
    mm_dtype = jnp.promote_types(lhs_n.dtype, rhs_n.dtype)
    out_node = output_nodes[0]

    call = mm_mod.matmul_epilogue(
        body, m=m, k=k, n=n, extra_shapes=extra_shapes,
        out_dtype=out_node.dtype, mm_dtype=mm_dtype,
        bm=meta["bm"], bn=meta["bn"], bk=meta["bk"], interpret=interpret)

    def run(*vals):
        out = call(vals[lhs_i], vals[rhs_i],
                   *[vals[i] for i in extra_is])
        return (out,)

    run.__name__ = "pallas_epilogue_matmul"
    return run
