"""Generated elementwise cluster kernels.

The compiler's fusion pass partitions a traced graph into elementwise
regions; this module *synthesizes* one Pallas kernel per region — the body
is generated from the cluster's op list, reading every external input once
from VMEM, running the region's ops on register values, and writing each
external output once.  That is the ArrayFire-JIT payoff (paper §4.1.1,
Fig. 2) made concrete: N dispatches collapse into a single kernel whose
arithmetic intensity grows with the cluster.

Off-TPU the kernel runs under ``interpret=True`` (reference semantics, same
numerics); shapes/dtypes the TPU lowering cannot tile fall back to a
per-cluster ``jax.jit`` of the same synthesized body — fusion is an
optimization, never a correctness constraint.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: dtypes the TPU tiling supports for generated elementwise bodies.
_TPU_DTYPES = (jnp.float32, jnp.bfloat16)


def make_body(nodes: Sequence[Any], input_ids: Sequence[int],
              output_ids: Sequence[int]) -> Callable:
    """Synthesize the cluster's straight-line body: values in, values out.

    Shared by every lowering: the Pallas kernel wraps it with ref
    reads/writes; the jit fallback compiles it directly.
    """
    nodes = tuple(nodes)
    input_ids = tuple(input_ids)
    output_ids = tuple(output_ids)

    def body(*vals):
        env = dict(zip(input_ids, vals))
        for n in nodes:
            env[n.uid] = n.fn(*[env[d] for d in n.inputs])
        return tuple(env[o] for o in output_ids)

    body.__name__ = f"cluster_{'_'.join(n.op for n in nodes[:4])}"
    return body


def pallas_supported(nodes: Sequence[Any], input_nodes: Sequence[Any],
                     on_tpu: bool) -> bool:
    """Can this cluster become a single ``pallas_call``?

    Requires one common shape across members and external inputs (the
    generated body does no in-kernel broadcasting) and — on TPU only —
    MXU/VPU-tileable shapes and dtypes; interpret mode accepts anything.
    """
    shapes = {tuple(n.shape) for n in nodes}
    shapes |= {tuple(n.shape) for n in input_nodes}
    if len(shapes) != 1:
        return False
    (shape,) = shapes
    if len(shape) == 0:
        return False
    if not on_tpu:
        return True
    if len(shape) < 2 or shape[-1] % 128 != 0 or shape[-2] % 8 != 0:
        return False
    dtypes = {jnp.dtype(n.dtype) for n in list(nodes) + list(input_nodes)}
    return all(d in _TPU_DTYPES for d in dtypes)


def build_cluster_kernel(nodes: Sequence[Any], input_nodes: Sequence[Any],
                         output_nodes: Sequence[Any],
                         interpret: bool = True) -> Callable:
    """One ``pallas_call`` for the whole cluster.

    Returns ``fn(*input_arrays) -> tuple(output_arrays)``; the kernel body
    is generated from the cluster's op list (see :func:`make_body`).
    """
    body = make_body(nodes, [n.uid for n in input_nodes],
                     [n.uid for n in output_nodes])
    n_in = len(input_nodes)

    def kernel(*refs):
        ins = [r[...] for r in refs[:n_in]]
        outs = body(*ins)
        for r, v in zip(refs[n_in:], outs):
            r[...] = v

    out_shape = [jax.ShapeDtypeStruct(tuple(n.shape), n.dtype)
                 for n in output_nodes]
    call = pl.pallas_call(kernel, out_shape=out_shape, interpret=interpret)

    def run(*vals):
        out = call(*vals)
        return tuple(out)

    run.__name__ = f"pallas_{body.__name__}"
    return run


def build_jit_cluster(nodes: Sequence[Any], input_nodes: Sequence[Any],
                      output_nodes: Sequence[Any]) -> Callable:
    """Per-cluster ``jax.jit`` fallback over the same synthesized body."""
    body = make_body(nodes, [n.uid for n in input_nodes],
                     [n.uid for n in output_nodes])
    return jax.jit(body)
