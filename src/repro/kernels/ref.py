"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x, y):
    return jnp.matmul(x, y)


def rms_norm(x, weight, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    ms = (x32 * x32).mean(-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps)
            * weight.astype(jnp.float32)).astype(x.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, softcap: float = 0.0):
    """q,k,v: [B, H, S, D] (kv heads already expanded to H)."""
    b, h, s, d = q.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = mask & (qpos >= kpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), v)


def attention_variant(q, k, v, *, mode: str = "softmax", scale: float = 1.0,
                      bias=None, bias_scale: float = 1.0):
    """Oracle for the compiler's parameterized attention template:
    ``act(scale * QK^T + bias_scale * bias) V`` with ``act`` softmax
    (row-normalized) or sigmoid (per-score, the normalizer-free variant).

    q, k, v: [..., S, D] with matching leading dims; ``bias`` must
    broadcast against the [..., Sq, Sk] score matrix (e.g. an ALiBi
    distance penalty or an additive mask).
    """
    scores = jnp.einsum("...qd,...kd->...qk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if bias is not None:
        scores = scores + bias_scale * bias.astype(jnp.float32)
    if mode == "sigmoid":
        w = jax.nn.sigmoid(scores)
    else:
        w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", w.astype(v.dtype), v)


def flash_decode(q, k, v, valid, *, scale: float | None = None):
    """q: [BH, D]; k,v: [BH, S, D]; valid: [S] or per-row [BH, S] bool
    -> [BH, D]."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    vm = valid if valid.ndim == 2 else valid[None, :]
    scores = jnp.einsum("nd,nsd->ns", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(vm, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(vm, w, 0.0)
    return jnp.einsum("ns,nsd->nd", w.astype(v.dtype), v)


def flash_verify(q, k, v, valid, *, scale: float | None = None):
    """Wide-verify oracle.  q: [N, T, D]; k,v: [N, S, D]; valid:
    [N, T, S] bool (per row and per query position) -> [N, T, D]."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    scores = jnp.einsum("ntd,nsd->nts", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(valid, w, 0.0)
    return jnp.einsum("nts,nsd->ntd", w.astype(v.dtype), v)


def ssd_chunk(x, dt, A, B, C):
    """Intra-chunk SSD + end-of-chunk states (single chunk, no carry-in).

    x: [B,H,NC,Q,P]; dt: [B,H,NC,Q]; A: [H]; B,C: [B,NC,Q,N].
    Returns y_diag [B,H,NC,Q,P], states [B,H,NC,P,N].
    """
    a = dt * A[None, :, None, None]                          # [B,H,NC,Q]
    q = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    seg = cs[..., :, None] - cs[..., None, :]
    tri = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(tri, jnp.exp(seg), 0.0)                    # [B,H,NC,Q,Q]
    scores = jnp.einsum("bcqn,bcsn->bcqs", C, B)
    y = jnp.einsum("bcqs,bhcqs,bhcs,bhcsp->bhcqp",
                   scores, L, dt, x)
    decay_to_end = jnp.exp(cs[..., -1:] - cs)                # [B,H,NC,Q]
    states = jnp.einsum("bcqn,bhcq,bhcq,bhcqp->bhcpn",
                        B, decay_to_end, dt, x)
    return y.astype(x.dtype), states.astype(jnp.float32)


def moe_gmm(h, w):
    """Grouped (per-expert) matmul: [E,C,D] @ [E,D,F] -> [E,C,F]."""
    return jnp.einsum("ecd,edf->ecf", h, w)
