"""Grouped (per-expert) matmul Pallas kernel for MoE expert FFNs.

[E, C, D] @ [E, D, F] -> [E, C, F]: the expert axis rides the grid (it is
the EP-sharded axis, so per shard E_local = E/ep programs), and each
(c, f) output tile accumulates over the D grid axis in fp32 VMEM scratch —
the same MXU-tiling discipline as kernels/matmul.py, lifted over groups.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(h_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    dd = pl.program_id(3)

    @pl.when(dd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        h_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(dd == n_d - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def moe_gmm(h, w, *, bc: int = 128, bf: int = 128, bd: int = 128,
            interpret: bool = False):
    """h: [E, C, D]; w: [E, D, F] -> [E, C, F]."""
    e, c, d = h.shape
    _, _, f = w.shape
    bc, bf, bd = min(bc, c), min(bf, f), min(bd, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0, (h.shape, w.shape)
    n_d = d // bd
    grid = (e, c // bc, f // bf, n_d)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, n_d=n_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ee, i, j, kk: (ee, i, kk)),
            pl.BlockSpec((1, bd, bf), lambda ee, i, j, kk: (ee, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ee, i, j, kk: (ee, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f),
                                       jnp.promote_types(h.dtype, w.dtype)),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(h, w)
