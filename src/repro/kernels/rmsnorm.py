"""Fused RMSNorm Pallas kernel.

Memory-bound op: unfused XLA does (read x, write ms) + (read x, read ms,
write out) — the fused kernel reads x once per (bn, D) VMEM tile, reduces
in fp32 registers, scales, and writes once: ~2·N·D bytes of HBM traffic
vs ~4·N·D.  Weight is staged once per program via a constant index map.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = (x * x).mean(-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    o_ref[...] = (x * inv * w_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "bn", "interpret"))
def rms_norm(x, weight, *, eps: float = 1e-6, bn: int = 256,
             interpret: bool = False):
    """x: [..., D]; weight: [D]."""
    orig_shape = x.shape
    d = orig_shape[-1]
    n = 1
    for s in orig_shape[:-1]:
        n *= s
    x2 = x.reshape(n, d)
    bn = min(bn, n)
    while n % bn != 0:
        bn -= 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out.reshape(orig_shape)
