"""Flash attention (fwd) Pallas kernel: online-softmax over (bq, bk) VMEM
tiles, causal + sliding-window masking, optional logit softcap.

TPU adaptation notes (vs the CUDA algorithm): tiles are MXU-aligned
(bq, bk multiples of 128 on real shapes; head_dim is the minor/lane dim),
running (m, l, acc) statistics live in VMEM scratch across the k-grid
axis (sequential grid traversal revisits the same q tile), and masking is
computed from broadcasted iotas — no [S, S] mask tensor ever exists in
HBM.  VMEM per program ≈ (bq·d + bk·d + bq·bk + bq·d) fp32 ≈ 260 KiB at
(128, 128, 128): far under the ~16 MiB budget, leaving room to raise bq.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, n_kb: int):
    qb, kb = pl.program_id(1), pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # [bq, d]
    k = k_ref[0]                                   # [bk, d]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask = mask & (qpos >= kpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, scores.max(-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _store():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "softcap", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, softcap: float = 0.0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q, k, v: [B, H, S, D] -> [B, H, S, D]."""
    b, h, s, d = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq, bk = min(bq, s), min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    bh = b * h
    q3 = q.reshape(bh, s, d)
    k3 = k.reshape(bh, s, d)
    v3 = v.reshape(bh, s, d)
    n_kb = s // bk
    grid = (bh, s // bq, n_kb)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, bq=bq, bk=bk,
                          n_kb=n_kb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, bk, d), lambda n, i, j: (n, j, 0)),
            pl.BlockSpec((1, bk, d), lambda n, i, j: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda n, i, j: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(b, h, s, d)
