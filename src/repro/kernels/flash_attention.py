"""Flash attention (fwd) Pallas kernel: online-softmax over (bq, bk) VMEM
tiles, causal + sliding-window masking, optional logit softcap.

TPU adaptation notes (vs the CUDA algorithm): tiles are MXU-aligned
(bq, bk multiples of 128 on real shapes; head_dim is the minor/lane dim),
running (m, l, acc) statistics live in VMEM scratch across the k-grid
axis (sequential grid traversal revisits the same q tile), and masking is
computed from broadcasted iotas — no [S, S] mask tensor ever exists in
HBM.  VMEM per program ≈ (bq·d + bk·d + bq·bk + bq·d) fp32 ≈ 260 KiB at
(128, 128, 128): far under the ~16 MiB budget, leaving room to raise bq.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, n_kb: int):
    qb, kb = pl.program_id(1), pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # [bq, d]
    k = k_ref[0]                                   # [bk, d]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask = mask & (qpos >= kpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, scores.max(-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _store():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def template_supported(*, sq: int, sk: int, d: int, dv: int,
                       dtypes, on_tpu: bool) -> bool:
    """Tile contract of :func:`attention_template`.

    Off-TPU the template runs under interpret mode and takes any shapes;
    on TPU every dimension must be MXU/lane aligned and the dtypes
    restricted — callers fall back to a per-cluster ``jax.jit`` when this
    returns False.
    """
    if min(sq, sk, d, dv) < 1:
        return False
    if not on_tpu:
        return True
    if sq % 128 or sk % 128 or d % 128 or dv % 128:
        return False
    return all(jnp.dtype(t) in (jnp.float32, jnp.bfloat16) for t in dtypes)


def _template_kernel(*refs, mode: str, scale: float, bias_scale: float,
                     k_layout: str, bias_spec: str, n_kb: int):
    q_ref, k_ref, v_ref = refs[0], refs[1], refs[2]
    idx = 3
    b_ref = None
    if bias_spec != "none":
        b_ref = refs[idx]
        idx += 1
    o_ref = refs[idx]
    m_scr, l_scr, acc_scr = refs[idx + 1], refs[idx + 2], refs[idx + 3]
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]
    dims = ((((1,), (1,)), ((), ())) if k_layout == "std"
            else (((1,), (0,)), ((), ())))
    s = jax.lax.dot_general(
        q, k_ref[0], dims, preferred_element_type=jnp.float32) * scale
    if bias_spec == "3d":
        s = s + bias_scale * b_ref[0].astype(jnp.float32)
    elif bias_spec == "2d":
        s = s + bias_scale * b_ref[...].astype(jnp.float32)

    if mode == "sigmoid":
        # sigmoid weights are linear in v: plain accumulation, no rescale
        p = 1.0 / (1.0 + jnp.exp(-s))
        acc_scr[...] += jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(kb == n_kb - 1)
        def _store_sigmoid():
            o_ref[0] = acc_scr[...].astype(o_ref.dtype)
    else:
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

        @pl.when(kb == n_kb - 1)
        def _store_softmax():
            o_ref[0] = (acc_scr[...]
                        / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "scale", "bias_scale",
                                             "k_layout", "bias_spec",
                                             "bq", "bk", "interpret"))
def attention_template(q, k, v, bias=None, *, mode: str = "softmax",
                       scale: float = 1.0, bias_scale: float = 1.0,
                       k_layout: str = "std", bias_spec: str = "none",
                       bq: int = 128, bk: int = 128,
                       interpret: bool = False):
    """Parameterized fused attention for compiler-matched subgraphs:
    ``out = act(scale·(q@kᵀ) + bias_scale·bias) @ v``.

    ``q``: [N, Sq, D]; ``k``: [N, Sk, D] (``k_layout="std"``) or
    [N, D, Sk] (``"kT"``, the rhs was already transposed); ``v``:
    [N, Sk, Dv]; ``bias``: None / [Sq, Sk] / [N, Sq, Sk] per
    ``bias_spec`` — custom additive masks and ALiBi slopes arrive here.
    ``mode`` selects the activation: online-softmax with running (m, l)
    statistics (always max-shifted — a mathematically-identical, safer
    ordering even when the matched graph skipped the shift) or sigmoid
    (linear in v, plain accumulation).  Built on the same tiling scheme
    as :func:`flash_attention`; grid (N, Sq/bq, Sk/bk).
    """
    n, sq, d = q.shape
    sk = k.shape[1] if k_layout == "std" else k.shape[2]
    dv = v.shape[2]
    bq, bk = min(bq, sq), min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    n_kb = sk // bk
    grid = (n, sq // bq, n_kb)
    in_specs = [pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0))]
    if k_layout == "std":
        in_specs.append(pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)))
    else:
        in_specs.append(pl.BlockSpec((1, d, bk), lambda b, i, j: (b, 0, j)))
    in_specs.append(pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)))
    operands = [q, k, v]
    if bias_spec == "3d":
        in_specs.append(pl.BlockSpec((1, bq, bk), lambda b, i, j: (b, i, j)))
        operands.append(bias)
    elif bias_spec == "2d":
        in_specs.append(pl.BlockSpec((bq, bk), lambda b, i, j: (i, j)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_template_kernel, mode=mode, scale=scale,
                          bias_scale=bias_scale, k_layout=k_layout,
                          bias_spec=bias_spec, n_kb=n_kb),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "softcap", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, softcap: float = 0.0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q, k, v: [B, H, S, D] -> [B, H, S, D]."""
    b, h, s, d = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq, bk = min(bq, s), min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    bh = b * h
    q3 = q.reshape(bh, s, d)
    k3 = k.reshape(bh, s, d)
    v3 = v.reshape(bh, s, d)
    n_kb = s // bk
    grid = (bh, s // bq, n_kb)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, bq=bq, bk=bk,
                          n_kb=n_kb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, bk, d), lambda n, i, j: (n, j, 0)),
            pl.BlockSpec((1, bk, d), lambda n, i, j: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda n, i, j: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(b, h, s, d)
