"""Mamba-2 SSD intra-chunk Pallas kernel.

The TPU-native form of the selective scan (DESIGN.md §6): instead of a
length-S sequential recurrence (hostile to the MXU), each (batch, head,
chunk) program computes

  y_diag  = (C·Bᵀ ∘ L ∘ dt) · x      — a masked attention-like matmul
  states  = Bᵀ · (decay·dt·x)         — the chunk's contribution to h

entirely in VMEM, with the decay matrix L = exp(segsum(dt·A)) built from
an in-register cumulative sum.  The O(n_chunks) inter-chunk recurrence —
tiny: [B,H,P,N] per chunk — stays in XLA (lax.scan), so the kernel covers
the FLOP-dominant part.  VMEM per program at (Q=256, P=64, N=128):
Q·P + 2·Q·N + Q·Q + Q·P + P·N ≈ 700 KiB fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, *,
                q: int):
    x = x_ref[0, 0, 0]                              # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # [Q]
    a = a_ref[0, 0, 0].astype(jnp.float32)          # [Q] (= dt * A, <= 0)
    bmat = b_ref[0, 0].astype(jnp.float32)          # [Q, N]
    cmat = c_ref[0, 0].astype(jnp.float32)          # [Q, N]

    cs = jnp.cumsum(a)                              # [Q]
    seg = cs[:, None] - cs[None, :]                 # [Q, Q]
    qi = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(qi >= ki, jnp.exp(seg), 0.0)      # [Q, Q] decay mask

    scores = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [Q, Q]
    w = scores * L * dt[None, :]
    y = jax.lax.dot_general(
        w, x.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # [Q, P]
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    decay_to_end = jnp.exp(cs[-1] - cs)              # [Q]
    xw = x.astype(jnp.float32) * (decay_to_end * dt)[:, None]  # [Q, P]
    st = jax.lax.dot_general(
        xw, bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # [P, N]
    st_ref[0, 0, 0] = st


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, dt, A, B, C, *, interpret: bool = False):
    """Intra-chunk SSD (matches kernels/ref.py::ssd_chunk).

    x: [B,H,NC,Q,P]; dt: [B,H,NC,Q]; A: [H]; B,C: [B,NC,Q,N].
    Returns (y_diag [B,H,NC,Q,P], states [B,H,NC,P,N]).
    """
    b, h, nc, q, p = x.shape
    n = B.shape[-1]
    a = dt * A[None, :, None, None]
    grid = (b, h, nc)
    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j, c: (i, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda i, j, c: (i, j, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, q, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, nc, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, B, C)
    return y, st
