"""Tiled MXU matmul Pallas kernel.

The TPU-native adaptation of "swap the source of truth for a primitive op"
(paper §5.2.4): the :class:`PallasBackend` routes *every* ``matmul`` in the
framework through this kernel.

Tiling: (bm, bk) x (bk, bn) VMEM tiles; the MXU wants multiples of 128 on
the contracting/output dims, the VPU lane layout wants minor dim = 128.
Accumulation is fp32 in a VMEM scratch accumulator across the K grid axis
(the grid revisits the same output tile along k), cast to the output dtype
on the last K step.  Default tiles (128, 128, 128) use
3 * 128 * 128 * 4 B ≈ 192 KiB of VMEM — far under the ~16 MiB budget, so
callers can raise bm/bn for better MXU utilization on large shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """2-D tiled matmul: (M, K) @ (K, N) -> (M, N)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by tiles ({bm},{bn},{bk})")
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    out_dtype = jnp.promote_types(x.dtype, y.dtype)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
