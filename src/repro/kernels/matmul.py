"""Tiled MXU matmul Pallas kernel.

The TPU-native adaptation of "swap the source of truth for a primitive op"
(paper §5.2.4): the :class:`PallasBackend` routes *every* ``matmul`` in the
framework through this kernel.

Tiling: (bm, bk) x (bk, bn) VMEM tiles; the MXU wants multiples of 128 on
the contracting/output dims, the VPU lane layout wants minor dim = 128.
Accumulation is fp32 in a VMEM scratch accumulator across the K grid axis
(the grid revisits the same output tile along k), cast to the output dtype
on the last K step.  Default tiles (128, 128, 128) use
3 * 128 * 128 * 4 B ≈ 192 KiB of VMEM — far under the ~16 MiB budget, so
callers can raise bm/bn for better MXU utilization on large shapes.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: VMEM budget a planned epilogue kernel may occupy (leaves headroom
#: under the ~16 MiB per-core budget).
_EPILOGUE_VMEM_LIMIT = 14 << 20


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """2-D tiled matmul: (M, K) @ (K, N) -> (M, N)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by tiles ({bm},{bn},{bk})")
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    out_dtype = jnp.promote_types(x.dtype, y.dtype)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)


# -- fused epilogues ---------------------------------------------------------


def plan_epilogue(*, m: int, k: int, n: int,
                  reductions: Sequence[tuple[Any, bool, int]],
                  extra_shapes: Sequence[tuple[int, ...]],
                  dtypes: Sequence[Any], on_tpu: bool,
                  vmem_limit: int = _EPILOGUE_VMEM_LIMIT
                  ) -> tuple[int, int, int] | None:
    """Validate an epilogue cluster against the fused kernel's contract
    and choose (bm, bn, bk) tiles; None means "don't claim".

    The epilogue body runs on one (bm, bn) output tile, so:

    * reductions must be keepdims over the last axis — and force
      ``bn == n`` (each tile must hold complete rows); ``axis=None``
      additionally forces ``bm == m`` (the whole matrix in one tile);
    * every extra operand must broadcast against a row/column tile:
      rank ≤ 2 with dims in {1, m} × {1, n} (rank-1 maps to columns);
    * the working set must fit VMEM; on TPU, shapes must be MXU/lane
      aligned and dtypes supported.
    """
    bm = 128 if m % 128 == 0 else m
    bn = 128 if n % 128 == 0 else n
    bk = 128 if k % 128 == 0 else k
    if reductions:
        bn = n
    for axis, keepdims, rank in reductions:
        if not keepdims or rank < 1:
            return None
        if axis is None:
            bm = m
        elif not isinstance(axis, int) or axis % rank != rank - 1:
            return None
    for s in extra_shapes:
        if len(s) == 0 or len(s) > 2:
            return None
        s2 = (1,) * (2 - len(s)) + tuple(s)
        if len(s) == 1 and s2[1] not in (1, n):
            return None
        if s2[0] not in (1, m) or s2[1] not in (1, n):
            return None
    vmem = 4 * (bm * bk + bk * bn + 2 * bm * bn)
    for s in extra_shapes:
        s2 = (1,) * (2 - len(s)) + tuple(s)
        vmem += 4 * ((bm if s2[0] == m else 1) * (bn if s2[1] == n else 1))
    if vmem > vmem_limit:
        return None
    if on_tpu:
        if m % 8 or n % 128 or k % 128:
            return None
        if any(jnp.dtype(d) not in (jnp.float32, jnp.bfloat16)
               for d in dtypes):
            return None
    return bm, bn, bk


def _bcast_spec(s: tuple[int, ...], m: int, n: int, bm: int, bn: int
                ) -> pl.BlockSpec:
    """BlockSpec for an epilogue operand: tiled along the dims it shares
    with the (m, n) output, pinned to block 0 along broadcast dims."""
    if len(s) == 1:
        if s[0] == n:
            return pl.BlockSpec((bn,), lambda i, j, kk: (j,))
        return pl.BlockSpec((1,), lambda i, j, kk: (0,))
    rtile, ctile = s[0] == m, s[1] == n
    blk = (bm if rtile else 1, bn if ctile else 1)

    def imap(i, j, kk, _r=rtile, _c=ctile):
        return (i if _r else 0, j if _c else 0)

    return pl.BlockSpec(blk, imap)


def _epilogue_kernel(*refs, body: Callable, n_extra: int, n_k: int,
                     mm_dtype: Any):
    x_ref, y_ref = refs[0], refs[1]
    extra_refs = refs[2:2 + n_extra]
    o_ref = refs[2 + n_extra]
    acc_ref = refs[3 + n_extra]
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _store():
        z = acc_ref[...].astype(mm_dtype)
        (out,) = body(z, *[r[...] for r in extra_refs])
        o_ref[...] = out.astype(o_ref.dtype)


def matmul_epilogue(body: Callable, *, m: int, k: int, n: int,
                    extra_shapes: Sequence[tuple[int, ...]],
                    out_dtype: Any, mm_dtype: Any, bm: int, bn: int,
                    bk: int, interpret: bool = False) -> Callable:
    """Tiled matmul with a synthesized epilogue fused at the store step.

    ``body(z, *extras)`` is the cluster's epilogue
    (:func:`repro.kernels.cluster.make_body` over the post-matmul
    members): it receives the (bm, bn) accumulator tile cast to the
    matmul's output dtype plus each extra operand's matching tile, and
    returns the single output tile.  Tiling must come from
    :func:`plan_epilogue` — it guarantees the per-tile replay is exact
    (reductions row-complete, operands broadcastable).
    """
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    for s in extra_shapes:
        in_specs.append(_bcast_spec(tuple(s), m, n, bm, bn))
    call = pl.pallas_call(
        functools.partial(_epilogue_kernel, body=body,
                          n_extra=len(extra_shapes), n_k=n_k,
                          mm_dtype=mm_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )
    return jax.jit(call)
