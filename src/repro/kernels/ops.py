"""jit'd public wrappers for the Pallas kernels.

Each wrapper auto-selects ``interpret=True`` off-TPU (Python emulation of
the kernel body — the CPU validation mode) and compiles to Mosaic on TPU.
The model substrate calls these via ``attention_impl="pallas"`` /
``PallasBackend``; tests sweep shapes/dtypes against kernels/ref.py.
"""

from __future__ import annotations

import jax

from . import flash_attention as _fa
from . import flash_decode as _fd
from . import matmul as _mm
from . import moe_gmm as _gmm
from . import rmsnorm as _rms
from . import ssd_chunk as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul(x, y, **kw):
    kw.setdefault("interpret", _interpret())
    return _mm.matmul(x, y, **kw)


def rms_norm(x, weight, **kw):
    kw.setdefault("interpret", _interpret())
    return _rms.rms_norm(x, weight, **kw)


def flash_attention(q, k, v, **kw):
    """q: [B,S,H,D] model-layout -> kernel layout [B,H,S,D] with GQA
    expansion handled here."""
    kw.setdefault("interpret", _interpret())
    b, s, h, d = q.shape
    kvh = k.shape[2]
    if kvh != h:
        rep = h // kvh
        k = jax.numpy.repeat(k, rep, axis=2)
        v = jax.numpy.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _fa.flash_attention(qt, kt, vt, **kw)
    return out.transpose(0, 2, 1, 3)


def flash_decode(q, k, v, valid, **kw):
    kw.setdefault("interpret", _interpret())
    return _fd.flash_decode(q, k, v, valid, **kw)


def flash_verify(q, k, v, valid, **kw):
    kw.setdefault("interpret", _interpret())
    return _fd.flash_verify(q, k, v, valid, **kw)


def ssd_chunk(x, dt, A, B, C, **kw):
    kw.setdefault("interpret", _interpret())
    return _ssd.ssd_chunk(x, dt, A, B, C, **kw)


def moe_gmm(h, w, **kw):
    kw.setdefault("interpret", _interpret())
    return _gmm.moe_gmm(h, w, **kw)
