"""Flash-decoding Pallas kernel: one query token vs a blocked KV cache.

The decode-side hot spot is *memory-bound* (stream the whole cache per
token), so the kernel's job is maximal HBM utilization: KV arrives in
(bk, d) VMEM tiles, partial (m, l, acc) statistics accumulate in scratch
across the k-grid axis, and the validity mask (cache length / ring
occupancy) streams alongside the cache — matching the shard-level math
in repro/serving/decode_attention.py (this kernel is the per-shard body;
the psum/pmax combine stays at the shard_map level).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, n_kb: int, per_row: bool):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                    # [1, d] row
    k = k_ref[0]                                    # [bk, d]
    valid = valid_ref[0] if per_row else valid_ref[...]  # [bk]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [1, bk]
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, scores.max(-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(valid[None, :], jnp.exp(scores - m_new), 0.0)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _store():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _verify_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, n_kb: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                    # [t, d]
    k = k_ref[0]                                    # [bk, d]
    valid = valid_ref[0]                            # [t, bk]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [t, bk]
    scores = jnp.where(valid, scores, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, scores.max(-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(scores - m_new), 0.0)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _store():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bk", "interpret"))
def flash_verify(q, k, v, valid, *, scale: float | None = None,
                 bk: int = 512, interpret: bool = False):
    """Wide-verify flash decoding for speculative decoding: ``t`` query
    tokens per row against the same blocked KV cache.

    q: [N, T, D]; k, v: [N, S, D]; valid: [N, T, S] bool per row *and*
    per query position (causal within the verified span: query ``t``
    may see cache positions ``<= pos + t``) -> [N, T, D].

    ``flash_decode`` is the T=1 special case; the (m, l, acc) online-
    softmax statistics simply gain a leading T axis and the whole span
    shares each streamed KV tile.
    """
    n, t, d = q.shape
    s = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bk = min(bk, s)
    assert s % bk == 0, (s, bk)
    n_kb = s // bk
    grid = (n, n_kb)
    out = pl.pallas_call(
        functools.partial(_verify_kernel, scale=scale, n_kb=n_kb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, t, bk), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, t, d), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, t, d), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((t, 1), jnp.float32),
            pltpu.VMEM((t, 1), jnp.float32),
            pltpu.VMEM((t, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
    return out


@functools.partial(jax.jit, static_argnames=("scale", "bk", "interpret"))
def flash_decode(q, k, v, valid, *, scale: float | None = None,
                 bk: int = 512, interpret: bool = False):
    """q: [N, D]; k, v: [N, S, D]; valid: [S] bool shared across rows, or
    [N, S] per-row (paged/continuous-batching caches where every slot
    sits at its own depth) -> [N, D]."""
    n, d = q.shape
    s = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bk = min(bk, s)
    assert s % bk == 0, (s, bk)
    n_kb = s // bk
    grid = (n, n_kb)
    per_row = valid.ndim == 2
    valid_spec = (pl.BlockSpec((1, bk), lambda i, j: (i, j)) if per_row
                  else pl.BlockSpec((bk,), lambda i, j: (j,)))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, n_kb=n_kb,
                          per_row=per_row),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
            valid_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1, d), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(q[:, None, :], k, v, valid)
    return out[:, 0, :]
