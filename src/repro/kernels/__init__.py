from . import cluster, ops, ref

__all__ = ["cluster", "ops", "ref"]
