from .rules import (DEFAULT_RULES, FSDP_RULES, ShardingRules, batch_spec,
                    make_rules)

__all__ = ["DEFAULT_RULES", "FSDP_RULES", "ShardingRules", "batch_spec",
           "make_rules"]
