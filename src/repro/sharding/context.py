"""Ambient mesh context for in-model sharding constraints.

Model code is mesh-agnostic; launchers install the active mesh here and
layers may then pin intermediate activations (e.g. MoE dispatch buffers)
with :func:`constrain`.  With no active mesh (unit tests, single-device
examples) every call is a no-op.
"""

from __future__ import annotations

import threading
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class _State(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.batch_axes: tuple = ("pod", "data")


_STATE = _State()


class active_mesh:
    """Context manager: ``with active_mesh(mesh, batch_axes=...): ...``

    ``batch_axes`` is the rule-derived mesh-axis set for the activation
    batch dimension — blocks re-pin activations to it at layer boundaries
    (GSPMD can drop batch sharding through masked attention einsums in the
    backward pass; measured 16x replication without this).
    """

    def __init__(self, mesh: Mesh | None, batch_axes=None):
        self.mesh = mesh
        self.batch_axes = tuple(batch_axes) if batch_axes else None

    def __enter__(self):
        self._prev = (_STATE.mesh, _STATE.batch_axes)
        _STATE.mesh = self.mesh
        if self.batch_axes is not None:
            _STATE.batch_axes = self.batch_axes
        return self.mesh

    def __exit__(self, *exc):
        _STATE.mesh, _STATE.batch_axes = self._prev
        return False


def get_active_mesh() -> Mesh | None:
    return _STATE.mesh


def constrain_batch(x) -> "jax.Array":
    """Pin dim 0 of an activation to the active batch axes (largest
    divisible prefix; no-op without an active mesh).

    Note: spilling undivided batch axes onto the sequence dim (naive SP)
    was measured to *blow up* the collective term — full attention over a
    seq-sharded activation makes GSPMD gather K/V per layer (§Perf log);
    proper SP needs a ring-attention shard_map, left as future work.
    """
    if _STATE.mesh is None:
        return x
    return constrain(x, (_STATE.batch_axes,) + (None,) * (x.ndim - 1))


def _resolve_axes(mesh, size: int, a) -> tuple[list, set]:
    """Largest prefix of candidate axes whose product divides ``size``."""
    cand = [m for m in ((a,) if isinstance(a, str) else tuple(a))
            if m in mesh.axis_names]
    while cand:
        total = 1
        for m in cand:
            total *= mesh.shape[m]
        if size % total == 0:
            break
        cand.pop()
    return cand, set(cand)


def constrain(x, axes: Sequence) -> jax.Array:
    """with_sharding_constraint against the ambient mesh (no-op without).

    ``axes`` entries are mesh axis names, tuples of them, or None; axes
    absent from the active mesh are dropped; non-divisible dims drop
    trailing candidate axes until the product divides (same policy as the
    rules engine).
    """
    mesh = _STATE.mesh
    if mesh is None:
        return x
    parts = []
    used: set = set()
    for size, a in zip(x.shape, axes):
        if a is None:
            parts.append(None)
            continue
        cand, _ = _resolve_axes(mesh, size, a)
        cand = [c for c in cand if c not in used]
        if not cand:
            parts.append(None)
        else:
            used.update(cand)
            parts.append(tuple(cand) if len(cand) > 1 else cand[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
