"""Ambient mesh context for in-model sharding constraints.

Model code is mesh-agnostic; launchers install the active mesh via
``repro.session(mesh=..., batch_axes=...)`` and layers may then pin
intermediate activations (e.g. MoE dispatch buffers) with
:func:`constrain`.  With no active mesh (unit tests, single-device
examples) every call is a no-op.

The mesh lives on the unified :class:`repro.runtime.Session`; the
historical ``active_mesh`` context manager remains as a deprecated shim
over the session stack.
"""

from __future__ import annotations

import warnings
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.runtime import stack as _rt


class active_mesh:
    """Deprecated shim: ``with active_mesh(mesh, batch_axes=...): ...``

    Equivalent to ``repro.session(mesh=mesh, batch_axes=...)``.
    ``batch_axes`` is the rule-derived mesh-axis set for the activation
    batch dimension — blocks re-pin activations to it at layer boundaries
    (GSPMD can drop batch sharding through masked attention einsums in the
    backward pass; measured 16x replication without this).
    """

    def __init__(self, mesh: Mesh | None, batch_axes=None):
        self.mesh = mesh
        self.batch_axes = tuple(batch_axes) if batch_axes else None

    def __enter__(self):
        warnings.warn(
            "active_mesh() is deprecated; use repro.session(mesh=..., "
            "batch_axes=...) instead", DeprecationWarning, stacklevel=2)
        overrides: dict = {"mesh": self.mesh}
        if self.batch_axes is not None:
            overrides["batch_axes"] = self.batch_axes
        _rt.push_session(_rt.current_session().replace(**overrides))
        return self.mesh

    def __exit__(self, *exc):
        _rt.pop_session()
        return False


def get_active_mesh() -> Mesh | None:
    return _rt.current_session().mesh


def get_batch_axes() -> tuple:
    """Mesh-axis candidates for the activation batch dimension."""
    return _rt.current_session().batch_axes


def constrain_batch(x) -> "jax.Array":
    """Pin dim 0 of an activation to the active batch axes (largest
    divisible prefix; no-op without an active mesh).

    Note: spilling undivided batch axes onto the sequence dim (naive SP)
    was measured to *blow up* the collective term — full attention over a
    seq-sharded activation makes GSPMD gather K/V per layer (§Perf log);
    proper SP needs a ring-attention shard_map, left as future work.
    """
    sess = _rt.current_session()
    if sess.mesh is None:
        return x
    return constrain(x, (sess.batch_axes,) + (None,) * (x.ndim - 1))


def _resolve_axes(mesh, size: int, a) -> tuple[list, set]:
    """Largest prefix of candidate axes whose product divides ``size``."""
    cand = [m for m in ((a,) if isinstance(a, str) else tuple(a))
            if m in mesh.axis_names]
    while cand:
        total = 1
        for m in cand:
            total *= mesh.shape[m]
        if size % total == 0:
            break
        cand.pop()
    return cand, set(cand)


def constrain(x, axes: Sequence) -> jax.Array:
    """with_sharding_constraint against the ambient mesh (no-op without).

    ``axes`` entries are mesh axis names, tuples of them, or None; axes
    absent from the active mesh are dropped; non-divisible dims drop
    trailing candidate axes until the product divides (same policy as the
    rules engine).
    """
    mesh = _rt.current_session().mesh
    if mesh is None:
        return x
    parts = []
    used: set = set()
    for size, a in zip(x.shape, axes):
        if a is None:
            parts.append(None)
            continue
        cand, _ = _resolve_axes(mesh, size, a)
        cand = [c for c in cand if c not in used]
        if not cand:
            parts.append(None)
        else:
            used.update(cand)
            parts.append(tuple(cand) if len(cand) > 1 else cand[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
