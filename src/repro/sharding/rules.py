"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Params carry logical axis names (via :class:`ParamMeta`); a rule table maps
them onto mesh axes.  The resolver enforces two invariants the hand-rolled
approach always gets wrong at 3am:

* a mesh axis is used at most once per PartitionSpec;
* a dimension is only sharded if its size is divisible by the product of
  the mesh axes assigned to it (e.g. granite's kv_heads=1 silently falls
  back to replication instead of failing at compile).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Default rule table. Order matters: first applicable rule wins.
# A logical axis may map to a tuple of mesh axes (sharded over both).
DEFAULT_RULES: tuple[tuple[str, Any], ...] = (
    ("batch",    ("pod", "data")),
    ("vocab",    "model"),
    ("heads",    "model"),
    ("kv_heads", "model"),
    ("mlp",      "model"),
    ("experts",  "model"),
    ("seq_shard", "model"),     # SP: sharded KV-cache sequence
    ("embed",    None),          # baseline: replicate embed dim
    ("layers",   None),          # scan axis
)

# FSDP variant: weight "embed" dims shard across the data axis (ZeRO-3
# flavor); optimizer state inherits it (ZeRO-1/2 follow for free since
# moments are param-shaped).
FSDP_RULES: tuple[tuple[str, Any], ...] = (
    ("batch",    ("pod", "data")),
    ("vocab",    "model"),
    ("heads",    "model"),
    ("kv_heads", "model"),
    ("mlp",      "model"),
    ("experts",  "model"),
    ("seq_shard", "model"),
    ("embed",    "data"),
    ("expert_mlp", None),
    ("layers",   None),
)


# EP+FSDP (beyond-paper §Perf variant): NO tensor parallelism on dense
# compute — the per-layer [tokens, d_model] activation all-reduces that
# dominate the baseline's collective term disappear entirely.  The model
# axis is reserved for expert parallelism (MoE all-to-alls are the *useful*
# collectives) and vocab TP (keeps big-vocab logits sharded); all other
# params FSDP-shard over data.  Dense archs get pure FSDP + vocab TP.
EP_FSDP_RULES: tuple[tuple[str, Any], ...] = (
    # with no dense TP the model axis must join the batch shard — otherwise
    # the model axis replicates the dense compute 16x (measured; §Perf log)
    ("batch",    ("pod", "data", "model")),
    ("vocab",    "model"),
    ("heads",    None),
    ("kv_heads", None),
    ("mlp",      None),
    ("experts",  "model"),
    ("seq_shard", "model"),
    ("embed",    ("data", "model")),
    ("expert_mlp", None),
    ("layers",   None),
)


@dataclass
class ShardingRules:
    rules: tuple[tuple[str, Any], ...] = DEFAULT_RULES
    # names that exist on the mesh; resolved lazily
    warnings: list[str] = field(default_factory=list)

    def mesh_axes_for(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        for name, target in self.rules:
            if name == logical:
                if target is None:
                    return ()
                return (target,) if isinstance(target, str) else tuple(target)
        return ()

    def spec(self, shape: Sequence[int], axes: Sequence[str | None],
             mesh: Mesh) -> P:
        used: set[str] = set()
        parts: list[Any] = []
        for size, logical in zip(shape, axes):
            cand = [a for a in self.mesh_axes_for(logical)
                    if a in mesh.axis_names and a not in used]
            # divisibility check: drop trailing axes until it divides
            while cand:
                total = 1
                for a in cand:
                    total *= mesh.shape[a]
                if size % total == 0:
                    break
                dropped = cand.pop()
                self.warnings.append(
                    f"axis {logical!r} (size {size}) not divisible by mesh "
                    f"axis {dropped!r}; falling back")
            if not cand:
                parts.append(None)
            else:
                used.update(cand)
                parts.append(tuple(cand) if len(cand) > 1 else cand[0])
        # strip trailing Nones for cleanliness
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def tree_specs(self, metas: Any, mesh: Mesh) -> Any:
        from repro.models.meta import ParamMeta, is_meta

        return jax.tree.map(
            lambda m: self.spec(m.shape, m.axes, mesh), metas,
            is_leaf=is_meta)

    def tree_shardings(self, metas: Any, mesh: Mesh) -> Any:
        from repro.models.meta import is_meta

        return jax.tree.map(
            lambda m: NamedSharding(mesh, self.spec(m.shape, m.axes, mesh)),
            metas, is_leaf=is_meta)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    """PartitionSpec for a [batch, ...] input batch."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None),
             *([None] * extra_dims))


def make_rules(variant: str = "baseline") -> ShardingRules:
    if variant in ("baseline", "tp"):
        return ShardingRules(DEFAULT_RULES)
    if variant == "fsdp":
        return ShardingRules(FSDP_RULES)
    if variant == "ep_fsdp":
        return ShardingRules(EP_FSDP_RULES)
    raise ValueError(f"unknown sharding variant {variant!r}")
