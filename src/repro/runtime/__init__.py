"""repro.runtime — the unified Session API.

One composable, nestable, thread-local context carrying every scoped
customization point: tensor backend, mesh + sharding rules + batch axes,
kernel overrides, precision policy, and memory manager.

    import repro

    with repro.session(backend="lazy", tag="fusion-study") as s:
        ...                       # everything dispatches through s
        print(s.describe())       # serializable provenance snapshot
"""

from .policies import (AnalysisPolicy, CompilerPolicy, KernelOverrides,
                       ObservabilityPolicy, PrecisionPolicy, PrefixPolicy,
                       ServingPolicy, SpeculativePolicy, resolve_dtype)
from .session import Session
from .stack import (current_session, default_session, mutate_current,
                    pop_session, push_session, session)

__all__ = [
    "Session", "KernelOverrides", "PrecisionPolicy", "ServingPolicy",
    "PrefixPolicy", "SpeculativePolicy",
    "CompilerPolicy", "AnalysisPolicy", "ObservabilityPolicy",
    "resolve_dtype",
    "session", "current_session", "default_session",
    "push_session", "pop_session", "mutate_current",
]
