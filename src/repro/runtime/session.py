"""The :class:`Session`: one composable context for the whole stack.

Flashlight's thesis (paper §4–§5) is that framework internals are open,
modular customization points.  Previously each point lived in its own
thread-local or kwarg: the tensor backend in ``core/tensor/dispatch.py``,
the mesh in ``sharding/context.py``, decode-attention overrides threaded
by hand as ``attend_fn``.  A Session bundles all of them into a single
value that can be entered for a scope (``repro.session(...)``), derived
(``Session.replace(...)``), inspected (``repro.current_session()``) and
snapshotted (``Session.describe()``) — so "the configuration this step
ran under" is one object, not an archaeology exercise.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

from .policies import (AnalysisPolicy, CompilerPolicy, KernelOverrides,
                       ObservabilityPolicy, PrecisionPolicy, ServingPolicy)

# Default mesh-axis candidates for the activation batch dimension; matches
# the historical sharding/context.py default.
DEFAULT_BATCH_AXES: tuple[str, ...] = ("pod", "data")


@dataclass(frozen=True)
class Session:
    """Immutable bundle of every scoped customization point.

    backend:
        tensor backend — a registry name (``"jnp"``, ``"lazy"``,
        ``"pallas"``, anything registered via ``register_backend``) or a
        ``TensorBackend`` instance.  Resolved lazily by
        :meth:`backend_instance` so constructing a Session never imports
        heavyweight backends.
    mesh / batch_axes:
        the active ``jax.sharding.Mesh`` (or None) and the mesh-axis
        candidates activations re-pin their batch dim to.
    sharding_rules:
        the rules object (``sharding.rules.make_rules(...)``) the mesh
        was planned with; carried for provenance and so layers can reach
        rule-derived facts without replumbing.
    kernels / precision / serving / compiler:
        see :class:`KernelOverrides` / :class:`PrecisionPolicy` /
        :class:`ServingPolicy` / :class:`CompilerPolicy`.
    memory:
        a ``MemoryManagerAdapter`` (host-side pool / trace-replay policy
        under study) or None.
    tag:
        free-form label that lands in ``describe()`` — name the scenario.
    """

    backend: Any = "jnp"
    mesh: Any = None
    batch_axes: tuple[str, ...] = DEFAULT_BATCH_AXES
    sharding_rules: Any = None
    kernels: KernelOverrides = field(default_factory=KernelOverrides)
    precision: PrecisionPolicy = field(default_factory=PrecisionPolicy)
    serving: ServingPolicy = field(default_factory=ServingPolicy)
    compiler: CompilerPolicy = field(default_factory=CompilerPolicy)
    analysis: AnalysisPolicy = field(default_factory=AnalysisPolicy)
    obs: ObservabilityPolicy = field(default_factory=ObservabilityPolicy)
    memory: Any = None
    tag: str = ""

    def __post_init__(self):
        if self.batch_axes is not None:
            object.__setattr__(self, "batch_axes", tuple(self.batch_axes))
        if isinstance(self.obs, bool):
            object.__setattr__(self, "obs",
                               ObservabilityPolicy(enabled=self.obs))
        elif isinstance(self.obs, dict):
            # a dict of knobs opts in unless it says otherwise:
            # session(obs={"max_events": N}) reads as "obs on, bounded"
            object.__setattr__(self, "obs", {"enabled": True, **self.obs})
        for name, cls in (("kernels", KernelOverrides),
                          ("precision", PrecisionPolicy),
                          ("serving", ServingPolicy),
                          ("compiler", CompilerPolicy),
                          ("analysis", AnalysisPolicy),
                          ("obs", ObservabilityPolicy)):
            val = getattr(self, name)
            if isinstance(val, dict):
                object.__setattr__(self, name, cls(**val))

    # -- derivation ---------------------------------------------------------
    def replace(self, **overrides) -> "Session":
        """A derived session; nested fields accept dicts of overrides:
        ``s.replace(kernels={"matmul": fn})`` keeps the other kernels."""
        if isinstance(overrides.get("obs"), bool):
            overrides["obs"] = ObservabilityPolicy(enabled=overrides["obs"])
        elif isinstance(overrides.get("obs"), dict):
            overrides["obs"] = {"enabled": True, **overrides["obs"]}
        for name in ("kernels", "precision", "serving", "compiler",
                     "analysis", "obs"):
            val = overrides.get(name)
            if isinstance(val, dict):
                overrides[name] = getattr(self, name).replace(**val)
        return dataclasses.replace(self, **overrides)

    # -- resolution ---------------------------------------------------------
    def backend_instance(self):
        """The live TensorBackend (registry names resolved on demand).

        Memoized per Session: this sits on the eager dispatch hot path
        (every ``ops.*`` primitive), so after the first resolution it is
        one dict lookup.  The import stays local — dispatch imports the
        runtime at module level, so the reverse edge must be lazy.
        """
        inst = self.__dict__.get("_backend_inst")
        if inst is None:
            b = self.backend
            if isinstance(b, str):
                from repro.core.tensor.dispatch import get_backend

                b = get_backend(b)
            inst = b
            object.__setattr__(self, "_backend_inst", inst)
        return inst

    # -- provenance ---------------------------------------------------------
    def describe(self) -> dict:
        """JSON-serializable snapshot for logs and benchmark provenance."""
        b = self.backend
        backend = b if isinstance(b, str) else getattr(
            b, "name", type(b).__name__)
        mesh = None
        if self.mesh is not None:
            mesh = {"axes": {k: int(v)
                             for k, v in dict(self.mesh.shape).items()},
                    "devices": int(self.mesh.devices.size)}
        rules = self.sharding_rules
        if rules is not None:
            rules = getattr(rules, "name", None) or type(rules).__name__
        memory = None
        if self.memory is not None:
            memory = {"manager": type(self.memory).__name__,
                      "capacity": int(getattr(self.memory, "capacity", 0))}
        compiler = self.compiler.describe()
        # per-pass stats from the most recent pipeline run through the
        # *resolved* backend (compiler-aware backends expose
        # `last_compile_report`); never force a resolution just to
        # describe.  Registry backends are process-wide singletons, so
        # only embed stats actually produced under THIS session's policy —
        # another session's run must not masquerade as our provenance.
        inst = self.__dict__.get("_backend_inst")
        if inst is None and not isinstance(self.backend, str):
            inst = self.backend
        report = getattr(inst, "last_compile_report", None)
        if (report is not None
                and getattr(inst, "last_compile_policy", None)
                == self.compiler):
            compiler["last_run"] = report
        return {
            "backend": backend,
            "mesh": mesh,
            "batch_axes": list(self.batch_axes or ()),
            "sharding_rules": rules,
            "kernels": self.kernels.describe(),
            "precision": self.precision.describe(),
            "serving": self.serving.describe(),
            "compiler": compiler,
            "analysis": self.analysis.describe(),
            "obs": self.obs.describe(),
            "memory": memory,
            "tag": self.tag,
        }
