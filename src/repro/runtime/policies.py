"""Kernel-override and precision policies carried by a :class:`Session`.

Both are small frozen dataclasses so sessions stay hashable-by-identity,
cheap to ``replace``, and serializable through ``describe()`` for logs and
benchmark provenance.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, fields
from typing import Any, Callable


def _callable_name(fn: Callable | None) -> str | None:
    if fn is None:
        return None
    name = getattr(fn, "__qualname__", None) or getattr(fn, "__name__", None)
    if name is None:
        name = type(fn).__name__
    mod = getattr(fn, "__module__", None)
    return f"{mod}.{name}" if mod else name


@dataclass(frozen=True)
class KernelOverrides:
    """Injectable kernels — the paper's §5 customization points as data.

    attention:
        full-sequence attention, ``fn(q, k, v, *, positions, causal,
        window, prefix_len, scale, cap) -> [B, S, H, Dv]``; replaces the
        config-selected implementation in :func:`gqa_attention`.
    decode_attention:
        cache attention for one decode step, ``fn(q, k, v, valid, *,
        scale, cap) -> [B, H, Dv]`` — the former ``attend_fn`` kwarg that
        used to be hand-threaded through ``ServeEngine`` and the model
        zoo (e.g. :func:`make_flash_decode_attend`).
    matmul:
        2-D contraction ``fn(lhs, rhs)``; consulted by ``ops.matmul``
        before backend dispatch (inject a Pallas tile without writing a
        whole backend).
    """

    attention: Callable | None = None
    decode_attention: Callable | None = None
    matmul: Callable | None = None

    def replace(self, **kw) -> "KernelOverrides":
        return dataclasses.replace(self, **kw)

    def describe(self) -> dict[str, str | None]:
        return {f.name: _callable_name(getattr(self, f.name))
                for f in fields(self)}


@dataclass(frozen=True)
class PrecisionPolicy:
    """Session-level dtype overrides applied when a model config is built.

    ``None`` leaves the architecture config's own choice in place.
    Strings keep the policy serializable; ``resolve_dtype`` maps them to
    jnp dtypes at the point of use.  ``cache_dtype`` follows the config
    convention: ``"compute"`` or ``"fp8"``.
    """

    param_dtype: str | None = None
    compute_dtype: str | None = None
    cache_dtype: str | None = None

    def replace(self, **kw) -> "PrecisionPolicy":
        return dataclasses.replace(self, **kw)

    def describe(self) -> dict[str, str | None]:
        return {"param_dtype": self.param_dtype,
                "compute_dtype": self.compute_dtype,
                "cache_dtype": self.cache_dtype}


@dataclass(frozen=True)
class PrefixPolicy:
    """Prefix-sharing knobs for the paged KV cache (see
    ``serving/prefix.py``).

    enabled:
        content-addressed block sharing: admissions whose prompts share
        a prefix map their leading block-table entries onto existing
        pool blocks (refcounted, copy-on-write on the first divergent
        write) instead of re-allocating and re-prefilling.  Off by
        default — sharing is an opt-in scenario like every other
        policy.  Requires chunked prefill and a model without
        sliding-window layers (ring caches are per-slot dense and
        cannot skip prefill); unsupported models silently degrade to
        no sharing.
    retain:
        keep a finished request's registered blocks in the radix tree
        (tree-referenced, reclaimed LRU under pool pressure) so *later*
        requests can hit them.  ``False`` shares only among
        concurrently active requests.
    partial:
        allow the match to end in one partially-overlapping block
        (copy-on-write at the first divergent token); ``False``
        restricts sharing to whole-block matches.
    """

    enabled: bool = False
    retain: bool = True
    partial: bool = True

    def replace(self, **kw) -> "PrefixPolicy":
        return dataclasses.replace(self, **kw)

    def describe(self) -> dict:
        return {"enabled": self.enabled, "retain": self.retain,
                "partial": self.partial}


@dataclass(frozen=True)
class SpeculativePolicy:
    """Speculative-decoding knobs for the paged KV cache (see
    ``serving/speculative.py``).

    enabled:
        draft-propose-k / wide-verify decoding: a cheap proposer guesses
        up to ``k`` tokens per slot and the target model scores all
        proposals in one batched wide forward against the paged cache;
        rejected suffixes roll back by truncating the slot's block
        table.  Greedy output is token-for-token identical to one-token
        decode.  Requires a paged cache on a model without
        sliding-window layers (ring caches cannot roll back);
        unsupported configurations silently degrade to plain decode.
    k:
        maximum tokens drafted per slot per round (the verify width is
        ``k + 1`` — the last accepted token plus k proposals).
    draft:
        proposer kind — ``"ngram"`` (self-drafting suffix matcher, no
        second model) or ``"model"`` (a small draft model passed to the
        engine as ``draft_model`` / ``draft_params``, e.g. mamba2_370m
        drafting for a transformer target).
    ngram:
        context length of the n-gram matcher (``"ngram"`` draft only):
        propose a continuation when the last ``ngram - 1`` tokens
        re-occur earlier in the sequence.
    """

    enabled: bool = False
    k: int = 4
    draft: str = "ngram"
    ngram: int = 3

    def __post_init__(self) -> None:
        if self.draft not in ("ngram", "model"):
            raise ValueError(f"unknown draft kind {self.draft!r}; "
                             f"known: ('ngram', 'model')")
        if self.k < 1:
            raise ValueError("speculative k must be >= 1")

    def replace(self, **kw) -> "SpeculativePolicy":
        return dataclasses.replace(self, **kw)

    def describe(self) -> dict:
        return {"enabled": self.enabled, "k": self.k,
                "draft": self.draft, "ngram": self.ngram}


@dataclass(frozen=True)
class ServingPolicy:
    """Serving-scenario knobs carried by a :class:`Session`.

    cache:
        KV-cache layout — ``"dense"`` (per-slot ``max_seq`` reservation,
        the compatibility path) or ``"paged"`` (fixed-size blocks shared
        through a per-slot block table; see ``serving/kv_cache.py``).
    block_size / num_blocks:
        paged layout: positions per block, and total pool blocks (None
        derives a pool large enough that every slot can reach
        ``max_seq`` — no preemption pressure; smaller pools exercise
        evict + requeue).
    scheduler:
        admission/preemption policy — a registry name (``"fifo"``,
        ``"sjf"``, ``"priority"``; see ``serving/scheduler.py``) or a
        ``Scheduler`` instance.
    allocator:
        which ``core/memory/manager.py`` policy hands out blocks:
        ``"caching"`` (recycles freed blocks) or ``"bump"`` (never
        reuses — the lower-bound baseline).
    prefill_chunk:
        prompt tokens consumed per jitted prefill call (chunked batched
        prefill); ``0`` falls back to the legacy one-decode-per-token
        admission path.
    prefix:
        :class:`PrefixPolicy` — content-addressed prefix sharing across
        requests in the paged cache.  Accepts a ``PrefixPolicy``, a
        kwargs dict, or a bare bool (``True`` = defaults with sharing
        on).
    routing:
        multi-replica routing policy for ``serving.Router`` /
        ``serving.serve()`` — a registry name (``"round_robin"``,
        ``"least_loaded"``, ``"prefix_affinity"``; see
        ``serving/router.py``) or a ``RoutingPolicy`` instance.
        Single-engine serving ignores it.
    speculative:
        :class:`SpeculativePolicy` — draft-propose / wide-verify
        decoding with block-table rollback.  Accepts a
        ``SpeculativePolicy``, a kwargs dict, or a bare bool (``True`` =
        defaults with speculation on).
    """

    cache: str = "dense"
    block_size: int = 16
    num_blocks: int | None = None
    scheduler: Any = "fifo"
    allocator: str = "caching"
    prefill_chunk: int = 16
    prefix: PrefixPolicy = PrefixPolicy()
    routing: Any = "round_robin"
    speculative: SpeculativePolicy = SpeculativePolicy()

    def __post_init__(self):
        pfx = self.prefix
        if isinstance(pfx, bool):
            pfx = PrefixPolicy(enabled=pfx)
        elif isinstance(pfx, dict):
            pfx = PrefixPolicy(**pfx)
        object.__setattr__(self, "prefix", pfx)
        spec = self.speculative
        if isinstance(spec, bool):
            spec = SpeculativePolicy(enabled=spec)
        elif isinstance(spec, dict):
            spec = SpeculativePolicy(**spec)
        object.__setattr__(self, "speculative", spec)

    def replace(self, **kw) -> "ServingPolicy":
        return dataclasses.replace(self, **kw)

    def describe(self) -> dict:
        sched = self.scheduler
        if not isinstance(sched, str):
            sched = getattr(sched, "name", None) or type(sched).__name__
        routing = self.routing
        if not isinstance(routing, str):
            routing = getattr(routing, "name", None) or type(routing).__name__
        return {"cache": self.cache, "block_size": self.block_size,
                "num_blocks": self.num_blocks, "scheduler": sched,
                "allocator": self.allocator,
                "prefill_chunk": self.prefill_chunk,
                "prefix": self.prefix.describe(),
                "routing": routing,
                "speculative": self.speculative.describe()}


@dataclass(frozen=True)
class CompilerPolicy:
    """Graph-compiler pipeline selection carried by a :class:`Session`.

    The lazy tensor backend routes every ``materialize`` through
    ``repro.compiler``: trace → passes → lowering.  This policy picks the
    pass pipeline and the lowering strategy; ``describe()`` lands in
    ``Session.describe()`` so every benchmark row records how its graphs
    were compiled.

    pipeline:
        ordered pass names run by the ``PassManager`` (see
        ``repro.compiler.passes.PASS_REGISTRY``); ``()`` is the legacy
        lazy path — no rewrites, node-at-a-time evaluation.  The default
        runs the matcher passes (``attention`` — softmax/sigmoid
        ``QK^TV`` subgraphs to the flash template; ``epilogue`` — matmul
        consumer cones into the tiled matmul kernel) before ``fuse``
        partitions the remainder into elementwise/reduction clusters.
    lowering:
        ``"auto"`` — fused clusters become *generated* Pallas kernels
        (``interpret=True`` off-TPU) dispatched by cluster kind
        (elementwise/reduction body, fused-epilogue matmul, attention
        template) with a per-cluster ``jax.jit`` fallback for
        unsupported ops/dtypes/tile contracts; ``"jit"`` — always the
        jit fallback; ``"eager"`` — clusters run un-compiled
        (debugging).
    fold_size_limit:
        constant folding only precomputes nodes up to this many elements
        (guards compile-time blowup on huge constants).
    min_cluster_size:
        fusion keeps clusters with at least this many nodes; smaller
        groups stay as individual dispatches.
    cache_programs:
        reuse compiled programs across materializations with an identical
        graph signature (opaque nodes — e.g. random ops — always
        recompile).
    """

    pipeline: tuple[str, ...] = ("cse", "fold", "dce",
                                 "attention", "epilogue", "fuse")
    lowering: str = "auto"
    fold_size_limit: int = 1 << 16
    min_cluster_size: int = 2
    cache_programs: bool = True

    def __post_init__(self):
        object.__setattr__(self, "pipeline", tuple(self.pipeline))

    @classmethod
    def legacy(cls) -> "CompilerPolicy":
        """The pre-compiler lazy path: no rewrites, eager node-by-node."""
        return cls(pipeline=(), lowering="eager", cache_programs=False)

    def replace(self, **kw) -> "CompilerPolicy":
        return dataclasses.replace(self, **kw)

    def describe(self) -> dict:
        return {"pipeline": list(self.pipeline), "lowering": self.lowering,
                "fold_size_limit": self.fold_size_limit,
                "min_cluster_size": self.min_cluster_size,
                "cache_programs": self.cache_programs}


@dataclass(frozen=True)
class AnalysisPolicy:
    """Static-analysis level carried by a :class:`Session`.

    The ``repro.analysis`` suite runs over every compiled graph — at
    trace time in ``repro.compile`` and on every lazy-backend
    materialization — without executing anything.  This policy selects
    how much runs and how findings are enforced:

    level:
        ``"off"``     — no analysis (maximum-throughput escape hatch);
        ``"default"`` — structural IR verification, closed-form
                        shape/dtype re-derivation, cluster/liveness +
                        VMEM-budget checks, numerics lint; ERROR-severity
                        findings raise :class:`~repro.analysis.AnalysisError`;
        ``"strict"``  — additionally verifies the IR *between passes*
                        (``PassManager`` verify mode), re-derives shapes
                        through ``jax.eval_shape`` for ops without
                        closed-form rules, audits the lowered step
                        schedule and memory plan, and promotes WARNING
                        findings (e.g. ``numerics.bf16-accum``) to fatal.
    vmem_limit_bytes:
        per-cluster VMEM budget the liveness analysis estimates peak
        residency against (default 16 MiB — the TPU core budget the
        hand-written kernels are tiled for).
    audit_serving:
        when true (and ``level`` is not ``"off"``), the serving engine
        audits its paged KV cache block tables after every release; at
        ``"strict"`` the audit runs regardless.
    """

    level: str = "default"
    vmem_limit_bytes: int = 16 * 1024 * 1024
    audit_serving: bool = False

    _LEVELS = ("off", "default", "strict")

    def __post_init__(self) -> None:
        if self.level not in self._LEVELS:
            raise ValueError(f"unknown analysis level {self.level!r}; "
                             f"known: {self._LEVELS}")

    @property
    def enabled(self) -> bool:
        return self.level != "off"

    @property
    def strict(self) -> bool:
        return self.level == "strict"

    @property
    def error_threshold(self) -> Any:
        """Severity at/above which findings are fatal (strict: WARNING)."""
        from repro.analysis.diagnostics import Severity

        return Severity.WARNING if self.strict else Severity.ERROR

    def replace(self, **kw: Any) -> "AnalysisPolicy":
        return dataclasses.replace(self, **kw)

    def describe(self) -> dict:
        return {"level": self.level,
                "vmem_limit_bytes": self.vmem_limit_bytes,
                "audit_serving": self.audit_serving}


@dataclass(frozen=True)
class ObservabilityPolicy:
    """Session-scoped tracing + metrics gate (see :mod:`repro.obs`).

    Off by default: with ``enabled=False`` every instrumentation site in
    the compiler, serving engine, and memory telemetry reduces to one
    attribute check returning ``None`` — near-zero cost.  Enable with
    ``repro.session(obs=True)`` (or ``obs={"max_events": ...}``).

    enabled:
        record spans / instants / metrics into this policy's
        :class:`~repro.obs.trace.Tracer`.
    max_events:
        retention bound across spans + instants + counter samples;
        beyond it events are dropped and counted (``dropped`` in the
        export metadata), keeping obs-on memory cost bounded.

    The tracer is created lazily and memoized **on the policy instance**:
    sessions derived via :meth:`Session.replace` keep the same policy
    object and therefore record into the same stream — that is how
    compiler, serving, and memory events from nested scopes land in one
    trace.  ``replace()`` returns a fresh policy and hence a fresh
    tracer.
    """

    enabled: bool = False
    max_events: int = 200_000

    def tracer(self) -> Any:
        """The policy's lazily-created ``repro.obs.Tracer`` (one per
        policy instance), or ``None`` when disabled."""
        if not self.enabled:
            return None
        inst = self.__dict__.get("_tracer")
        if inst is None:
            from repro.obs.trace import Tracer

            inst = Tracer(max_events=self.max_events)
            object.__setattr__(self, "_tracer", inst)
        return inst

    def replace(self, **kw) -> "ObservabilityPolicy":
        return dataclasses.replace(self, **kw)

    def describe(self) -> dict:
        out: dict[str, Any] = {"enabled": self.enabled,
                               "max_events": self.max_events}
        inst = self.__dict__.get("_tracer")
        if inst is not None:
            out["recorded"] = inst.describe()
        return out


_DTYPE_ALIASES = {
    "f32": "float32", "fp32": "float32", "float32": "float32",
    "f16": "float16", "fp16": "float16", "float16": "float16",
    "bf16": "bfloat16", "bfloat16": "bfloat16",
}


def resolve_dtype(name: str) -> Any:
    """Map a policy dtype string to the jnp dtype object."""
    import jax.numpy as jnp

    try:
        return getattr(jnp, _DTYPE_ALIASES[name.lower()])
    except KeyError:
        raise ValueError(
            f"unknown precision dtype {name!r}; "
            f"known: {sorted(set(_DTYPE_ALIASES))}") from None
