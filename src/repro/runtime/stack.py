"""The nestable, thread-local session stack.

This module owns the ONLY scoped-state thread-local in the codebase; the
legacy entry points (``core/tensor/dispatch.py``, ``sharding/context.py``)
are shims over it.  Each thread sees:

* an optional stack of explicitly entered sessions (``repro.session``),
* beneath it an *ambient* session, lazily initialized from the process
  default — so worker threads start clean, and the legacy imperative
  ``set_backend(...)`` can still mutate the current scope in place.
"""

from __future__ import annotations

import contextlib
import threading

from .session import Session

_DEFAULT = Session()


class _Stack(threading.local):
    def __init__(self):
        self.stack: list[Session] = []
        self.ambient: Session | None = None


_STACK = _Stack()


def default_session() -> Session:
    """The process-wide root session (what a fresh thread sees)."""
    return _DEFAULT


def current_session() -> Session:
    """Innermost active session for this thread (never None)."""
    if _STACK.stack:
        return _STACK.stack[-1]
    if _STACK.ambient is None:
        _STACK.ambient = _DEFAULT
    return _STACK.ambient


def push_session(sess: Session) -> Session:
    """Low-level enter (prefer the ``session`` context manager)."""
    _STACK.stack.append(sess)
    return sess


def pop_session() -> Session:
    """Low-level exit; raises if the stack is empty."""
    return _STACK.stack.pop()


def mutate_current(**overrides) -> Session:
    """Imperatively rewrite the innermost scope (legacy ``set_backend``).

    Inside a ``with session(...)`` block this edits that block's session
    (restored on exit, exactly like the old thread-local swap); outside
    any block it edits the thread's ambient session.
    """
    new = current_session().replace(**overrides)
    if _STACK.stack:
        _STACK.stack[-1] = new
    else:
        _STACK.ambient = new
    return new


@contextlib.contextmanager
def session(base: Session | None = None, **overrides):
    """Enter a session scope: ``with repro.session(backend="lazy"): ...``

    With no ``base``, overrides derive from the current session, so
    scopes compose — entering ``session(mesh=m)`` inside
    ``session(backend="pallas")`` keeps the pallas backend.  Passing a
    ``Session`` as ``base`` enters it verbatim (plus any overrides).
    The previous state is restored on exit even if the body raises.
    """
    if base is None:
        base = current_session()
    elif not isinstance(base, Session):
        raise TypeError(
            f"session() base must be a Session, got {type(base).__name__}")
    new = base.replace(**overrides) if overrides else base
    push_session(new)
    try:
        yield new
    finally:
        pop_session()
